"""Model specifications shared by the L2 model, the AOT lowering, and tests.

Two MoE configurations mirror the paper's evaluation models at reduced
scale (see DESIGN.md §2 for the substitution argument):

- ``gpt2_moe_mini``  ~ GPT2-moe   (8 experts/layer, top-2, GPT-2 block)
- ``dsv2_mini``      ~ Deepseek-v2-lite (many routed experts + shared
  experts, top-4)

The hyper-parameters here are the single source of truth: ``aot.py``
emits them into ``artifacts/manifest.json`` and the rust runtime reads
them from there — rust never hard-codes a model shape.
"""

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class ModelSpec:
    """Hyper-parameters of one MoE model."""

    name: str
    hidden: int          # H — token embedding width
    layers: int          # L — number of MoE transformer blocks
    experts: int         # K — routed experts per layer
    topk: int            # experts activated per token
    ffn: int             # F — expert FFN inner width
    shared_experts: int  # DeepseekMoE-style always-on experts (part of F_l)
    shared_ffn: int      # inner width of the shared expert (0 if none)
    heads: int           # attention heads
    vocab: int           # byte-level vocabulary
    max_seq: int         # T — KV cache capacity (prefill + decode budget)
    act: str             # expert activation: "gelu" | "silu"

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


# Sequence-length buckets for prefill (S=128) and decode (S=1).
SEQ_BUCKETS: List[int] = [1, 128]

# Token-count buckets for the expert FFN artifact. Power-of-two so the
# Pallas token-block tiling divides evenly (see kernels/moe_ffn.py).
EXPERT_BUCKETS: List[int] = [1, 2, 4, 8, 16, 32, 64, 128]


GPT2_MOE_MINI = ModelSpec(
    name="gpt2_moe_mini",
    hidden=128,
    layers=4,
    experts=8,
    topk=2,
    ffn=256,
    shared_experts=0,
    shared_ffn=0,
    heads=4,
    vocab=256,
    max_seq=192,
    act="gelu",
)

DSV2_MINI = ModelSpec(
    name="dsv2_mini",
    hidden=128,
    layers=6,
    experts=16,
    topk=4,
    ffn=128,
    shared_experts=1,
    shared_ffn=256,
    heads=4,
    vocab=256,
    max_seq=192,
    act="silu",
)

MODELS = {m.name: m for m in (GPT2_MOE_MINI, DSV2_MINI)}
