"""Layer-1 Pallas kernel: the expert FFN — the paper's compute hot-spot.

Remoe's experts are plain 2-layer FFNs (``act(x·W1 + b1)·W2 + b2``)
executed on CPU cores in the paper (LibTorch GEMM). For the TPU-shaped
reproduction we re-think the decomposition (DESIGN.md §3):

- The **token dimension** is tiled into blocks of ``BN`` rows — the MXU
  systolic array wants ≥8×128 operand tiles; token buckets are powers of
  two so blocks divide evenly and no masking is needed.
- The **FFN inner dimension** is tiled into blocks of ``BF`` columns so
  one (x-block, W1-block, W2-block) working set fits comfortably in VMEM
  (~16 MB/core); the grid's second axis walks the FFN blocks and
  accumulates partial ``h_blk @ W2_blk`` products into the output block —
  this is the HBM↔VMEM schedule that replaces the paper's threadblock
  decomposition.
- Accumulation is f32 regardless of input dtype (MXU-style accumulate).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO that the rust runtime
runs. Correctness against ``ref.expert_ffn`` is enforced by pytest +
hypothesis and again from rust integration tests.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. BN: token-block rows (MXU sublane-friendly). BF: FFN-column
# block. With H=128, F<=256, f32: x-block 64x128 (32 KB) + W1 block
# 128x128 (64 KB) + W2 block 128x128 (64 KB) + h 64x128 + out 64x128
# ~ 256 KB per step, far under VMEM; chosen to keep the double-buffered
# pipeline resident. See DESIGN.md §8 for the footprint table.
BN = 64
BF = 128


def _act(h, act: str):
    if act == "gelu":
        return jax.nn.gelu(h, approximate=False)
    if act == "silu":
        return jax.nn.silu(h)
    raise ValueError(f"unknown activation {act!r}")


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, act: str,
                nf_blocks: int):
    """One grid step: token block i × FFN block j.

    Computes ``act(x_i @ W1[:, j] + b1[j]) @ W2[j, :]`` and accumulates
    into ``o_ref`` (initialised with the output bias on the first FFN
    block so the bias is added exactly once).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b2_ref[...], o_ref.shape)

    x = x_ref[...].astype(jnp.float32)
    h = jnp.dot(x, w1_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    h = _act(h + b1_ref[...].astype(jnp.float32), act)
    part = jnp.dot(h, w2_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    o_ref[...] = o_ref[...] + part.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act",))
def expert_ffn(x, w1, b1, w2, b2, act: str = "gelu"):
    """Pallas expert FFN. Shapes: x [n,H], w1 [H,F], b1 [F], w2 [F,H],
    b2 [H] → [n,H]. ``n`` and ``F`` must be multiples of the tile sizes
    or smaller than them (buckets guarantee this)."""
    n, hidden = x.shape
    f = w1.shape[1]
    bn = min(BN, n)
    bf = min(BF, f)
    assert n % bn == 0 and f % bf == 0, (n, f)
    grid = (n // bn, f // bf)

    return pl.pallas_call(
        functools.partial(_ffn_kernel, act=act, nf_blocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, hidden), lambda i, j: (i, 0)),   # x
            pl.BlockSpec((hidden, bf), lambda i, j: (0, j)),   # W1 cols
            pl.BlockSpec((bf,), lambda i, j: (j,)),            # b1
            pl.BlockSpec((bf, hidden), lambda i, j: (j, 0)),   # W2 rows
            pl.BlockSpec((hidden,), lambda i, j: (0,)),        # b2
        ],
        out_specs=pl.BlockSpec((bn, hidden), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hidden), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def vmem_footprint_bytes(n: int, hidden: int, f: int,
                         dtype_bytes: int = 4) -> int:
    """Static VMEM working-set estimate for one grid step (used by the
    DESIGN.md §8 perf analysis — interpret mode has no real VMEM)."""
    bn, bf = min(BN, n), min(BF, f)
    x_blk = bn * hidden
    w1_blk = hidden * bf
    w2_blk = bf * hidden
    h_blk = bn * bf
    o_blk = bn * hidden
    vecs = bf + hidden
    return (x_blk + w1_blk + w2_blk + h_blk + o_blk + vecs) * dtype_bytes


def mxu_flops(n: int, hidden: int, f: int) -> int:
    """MACs×2 for one expert call — the roofline numerator."""
    return 2 * n * hidden * f * 2  # two GEMMs
