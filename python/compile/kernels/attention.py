"""Layer-1 Pallas kernel: multi-head attention over a KV cache.

The paper keeps attention (the "non-expert module" F_l) on the GPU; here
it is the second Pallas kernel of the stack. TPU mapping (DESIGN.md §3):

- Grid over **heads**: each grid step computes one head's
  ``softmax(q·kᵀ/√d + mask)·v`` with the whole [S,T] score tile resident
  in VMEM (S≤128, T≤192 ⇒ ≤ 96 KB f32 — trivially resident; for larger
  S/T the natural extension is a second grid axis over query blocks).
- The additive mask is precomputed in the surrounding jax function from
  the scalar cache position (cheap, fused by XLA) and streamed per block;
  this keeps the kernel free of scalar-prefetch plumbing, which the
  interpret-mode CPU path doesn't exercise anyway.
- Scores and the softmax run in f32 (VPU), the two contractions target
  the MXU with ``preferred_element_type=f32``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    """One head: q [1,S,hd], k/v [1,T,hd], mask [S,T] additive → o [1,S,hd]."""
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd)) + mask_ref[...]
    # Numerically-stable softmax on the VPU.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32
                       ).astype(o_ref.dtype)


@jax.jit
def attention_core(q, k, v, mask):
    """Pallas attention core. q [S,nh,hd]; k,v [T,nh,hd]; mask [S,T]
    additive → [S,nh,hd]. Grid over heads."""
    s, nh, hd = q.shape
    t = k.shape[0]
    # [nh, S, hd] layout so each head is a contiguous block.
    qh = jnp.swapaxes(q, 0, 1)
    kh = jnp.swapaxes(k, 0, 1)
    vh = jnp.swapaxes(v, 0, 1)

    out = pl.pallas_call(
        _attn_kernel,
        grid=(nh,),
        in_specs=[
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((s, t), lambda h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nh, s, hd), q.dtype),
        interpret=True,
    )(qh.reshape(nh, s, hd), kh.reshape(nh, t, hd),
      vh.reshape(nh, t, hd), mask)
    return jnp.swapaxes(out, 0, 1)


def vmem_footprint_bytes(s: int, t: int, hd: int,
                         dtype_bytes: int = 4) -> int:
    """Static VMEM working-set estimate for one head's grid step."""
    return (s * hd + 2 * t * hd + 2 * s * t + s * hd) * dtype_bytes
