"""Pure-jnp oracles for the Pallas kernels and the L2 model blocks.

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops only. ``python/tests`` sweeps shapes
and dtypes with hypothesis and asserts ``allclose`` between kernel and
oracle; the rust integration tests independently re-check the lowered
artifacts against a pure-rust implementation of the same math.
"""

import jax.numpy as jnp
from jax.nn import gelu, silu, softmax


def layernorm(x, g, b, eps: float = 1e-5):
    """LayerNorm over the last axis with learned gain/bias."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def expert_ffn(x, w1, b1, w2, b2, act: str = "gelu"):
    """Oracle for the expert FFN: ``act(x @ w1 + b1) @ w2 + b2``.

    x: [n, H]; w1: [H, F]; b1: [F]; w2: [F, H]; b2: [H].
    """
    h = x @ w1 + b1
    h = gelu(h, approximate=False) if act == "gelu" else silu(h)
    return h @ w2 + b2


def attention_core(q, k, v, mask):
    """Oracle for multi-head attention over cached keys/values.

    q: [S, nh, hd]; k, v: [T, nh, hd]; mask: [S, T] additive (0 or -inf).
    Returns [S, nh, hd].
    """
    hd = q.shape[-1]
    # [nh, S, T]
    scores = jnp.einsum("snd,tnd->nst", q, k) / jnp.sqrt(jnp.float32(hd))
    scores = scores + mask[None, :, :]
    p = softmax(scores, axis=-1)
    return jnp.einsum("nst,tnd->snd", p, v)


def causal_cache_mask(s: int, t: int, pos0):
    """Additive mask: query row i may attend to cache slot j iff
    ``j <= pos0 + i`` (prefix of length pos0 plus causal self-block)."""
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    ok = cols <= (pos0 + rows)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention_block(h, ln_g, ln_b, wqkv, bqkv, wo, bo, k_cache, v_cache,
                    pos0, heads: int):
    """Oracle for the full attention artifact (pre-LN residual block).

    Returns (h_out [S,H], k_new [S,H], v_new [S,H]) — rust scatters
    k_new/v_new into its cache buffers at ``pos0``.
    """
    s, hidden = h.shape
    t = k_cache.shape[0]
    hd = hidden // heads
    x = layernorm(h, ln_g, ln_b)
    qkv = x @ wqkv + bqkv
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)

    # Write the fresh K/V rows into the cache view used for scoring.
    from jax import lax
    k_all = lax.dynamic_update_slice(k_cache, k_new, (pos0, 0))
    v_all = lax.dynamic_update_slice(v_cache, v_new, (pos0, 0))

    qh = q.reshape(s, heads, hd)
    kh = k_all.reshape(t, heads, hd)
    vh = v_all.reshape(t, heads, hd)
    mask = causal_cache_mask(s, t, pos0)
    out = attention_core(qh, kh, vh, mask).reshape(s, hidden)
    h_out = h + out @ wo + bo
    return h_out, k_new, v_new


def topk_iterative(logits, k: int):
    """top-k via k rounds of argmax + masking.

    Functionally identical to ``lax.top_k`` (ties break to the lower
    index) but lowers to reduce/scatter ops only: jax ≥ 0.5 lowers
    ``lax.top_k`` to a dedicated ``topk(..., largest=true)`` HLO custom
    instruction that the rust side's xla_extension 0.5.1 text parser
    rejects, so the artifacts must avoid it.
    """
    s = logits.shape[0]
    rows = jnp.arange(s)
    masked = logits
    vals, idxs = [], []
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)
        vals.append(jnp.take_along_axis(logits, idx[:, None], axis=-1)[:, 0])
        idxs.append(idx)
        masked = masked.at[rows, idx].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def gate_block(h, ln_g, ln_b, wg, topk: int):
    """Oracle for the gate artifact.

    Returns (xln [S,H], weights [S,topk], indices [S,topk] i32).
    Router weights are softmax over the selected top-k logits
    (Mixtral-style renormalisation).
    """
    xln = layernorm(h, ln_g, ln_b)
    logits = xln @ wg
    top_vals, top_idx = topk_iterative(logits, topk)
    w = softmax(top_vals, axis=-1)
    return xln, w, top_idx.astype(jnp.int32)


def embed(ids, wte, wpe, pos0):
    """Oracle for the embedding artifact: token + absolute position."""
    s = ids.shape[0]
    tok = wte[ids]
    positions = pos0 + jnp.arange(s)
    pos = wpe[positions]
    return tok + pos


def lm_head(h, lnf_g, lnf_b, wte):
    """Oracle for the LM head: final LN then tied-embedding projection."""
    x = layernorm(h, lnf_g, lnf_b)
    return x @ wte.T


def moe_layer(h, params, spec):
    """Oracle for one full MoE block (attention + gate + experts),
    used by the model-level shape/numerics tests.

    ``params`` is the per-layer dict produced by tests; ``spec`` is a
    ModelSpec. Dense reference: every expert computed, masked combine.
    """
    h, _, _ = attention_block(
        h, params["ln1_g"], params["ln1_b"], params["wqkv"], params["bqkv"],
        params["wo"], params["bo"], params["k_cache"], params["v_cache"],
        0, spec.heads)
    xln, w, idx = gate_block(h, params["ln2_g"], params["ln2_b"],
                             params["wg"], spec.topk)
    moe_out = jnp.zeros_like(h)
    for k in range(spec.experts):
        ek = expert_ffn(xln, params["w1"][k], params["b1"][k],
                        params["w2"][k], params["b2"][k], spec.act)
        # weight of expert k for each token (0 if not routed)
        sel = (idx == k).astype(h.dtype) * w
        wk = sel.sum(axis=-1, keepdims=True)
        moe_out = moe_out + wk * ek
    if spec.shared_experts:
        moe_out = moe_out + expert_ffn(
            xln, params["sw1"], params["sb1"], params["sw2"], params["sb2"],
            spec.act)
    return h + moe_out
