"""AOT lowering: jax entry points → HLO **text** artifacts + manifest.

Interchange is HLO text, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Writes one ``<model>__<entry>.hlo.txt`` per artifact plus
``manifest.json`` describing every model's hyper-parameters and every
artifact's input shapes — the rust runtime is entirely manifest-driven.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .specs import EXPERT_BUCKETS, MODELS, SEQ_BUCKETS


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True so the
    rust side unwraps a single tuple result)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_entry(sds) -> dict:
    return {"shape": list(sds.shape), "dtype": str(sds.dtype)}


def _inputs_fingerprint() -> str:
    """Hash of the compile-path sources, so `make artifacts` can skip
    re-lowering when nothing changed."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(base)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma-separated subset of models to lower")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    fingerprint = _inputs_fingerprint()
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            old = json.load(fh)
        if old.get("fingerprint") == fingerprint:
            print(f"artifacts up-to-date (fingerprint {fingerprint})")
            return

    manifest = {"fingerprint": fingerprint,
                "seq_buckets": SEQ_BUCKETS,
                "expert_buckets": EXPERT_BUCKETS,
                "models": {}, "artifacts": []}

    for name in args.models.split(","):
        spec = MODELS[name]
        manifest["models"][name] = {
            "hidden": spec.hidden, "layers": spec.layers,
            "experts": spec.experts, "topk": spec.topk, "ffn": spec.ffn,
            "shared_experts": spec.shared_experts,
            "shared_ffn": spec.shared_ffn, "heads": spec.heads,
            "vocab": spec.vocab, "max_seq": spec.max_seq, "act": spec.act,
        }
        eps = model_lib.entry_points(spec, SEQ_BUCKETS, EXPERT_BUCKETS)
        for ep_name, (fn, ex_args, meta) in eps.items():
            fname = ep_name.replace("/", "__") + ".hlo.txt"
            lowered = jax.jit(fn).lower(*ex_args)
            text = to_hlo_text(lowered)
            with open(os.path.join(args.out_dir, fname), "w") as fh:
                fh.write(text)
            manifest["artifacts"].append({
                "name": ep_name, "file": fname, "model": name,
                "kind": meta["kind"], "bucket": meta["bucket"],
                "inputs": [shape_entry(a) for a in ex_args],
            })
            print(f"lowered {ep_name:40s} -> {fname} ({len(text)} chars)",
                  file=sys.stderr)

    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to "
          f"{args.out_dir}")


if __name__ == "__main__":
    main()
