"""Layer-2: the MoE model's jax entry points, calling the Pallas kernels.

Rather than lowering one monolithic forward pass, the model is exported
as five entry-point families (embed / attn / gate / expert_ffn / lm_head)
with **weights as runtime arguments**. This is what gives the rust
coordinator the paper's freedom of placement: the *same* ``expert_ffn``
artifact backs local experts inside the main-model function, remote
experts inside separate serverless functions, and all four baseline
deployments — placement is purely an L3 decision.

Shapes are static per artifact (PJRT AOT requires it); sequence lengths
and expert token counts are bucketed (specs.SEQ_BUCKETS /
specs.EXPERT_BUCKETS) and rust pads up to the nearest bucket.

The KV cache lives in rust: ``attn`` takes the cache contents as inputs
and returns the fresh K/V rows for rust to scatter back at ``pos0``.
"""

import functools
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .specs import ModelSpec
from .kernels import attention as attn_kernel
from .kernels import moe_ffn as ffn_kernel
from .kernels import ref


def make_embed(spec: ModelSpec, s: int) -> Tuple[Callable, List]:
    """``(ids[S] i32, wte[V,H], wpe[T,H], pos0[] i32) → (h[S,H],)``"""

    def fn(ids, wte, wpe, pos0):
        tok = jnp.take(wte, ids, axis=0)
        positions = pos0 + jnp.arange(s, dtype=jnp.int32)
        pos = jnp.take(wpe, positions, axis=0)
        return (tok + pos,)

    args = [
        jax.ShapeDtypeStruct((s,), jnp.int32),
        jax.ShapeDtypeStruct((spec.vocab, spec.hidden), jnp.float32),
        jax.ShapeDtypeStruct((spec.max_seq, spec.hidden), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    return fn, args


def make_attn(spec: ModelSpec, s: int) -> Tuple[Callable, List]:
    """Pre-LN attention block over the KV cache (one layer).

    ``(h[S,H], ln_g[H], ln_b[H], wqkv[H,3H], bqkv[3H], wo[H,H], bo[H],
       k_cache[T,H], v_cache[T,H], pos0[] i32)
       → (h_out[S,H], k_new[S,H], v_new[S,H])``
    """
    hidden, heads, t = spec.hidden, spec.heads, spec.max_seq
    hd = spec.head_dim

    def fn(h, ln_g, ln_b, wqkv, bqkv, wo, bo, k_cache, v_cache, pos0):
        x = ref.layernorm(h, ln_g, ln_b)
        qkv = x @ wqkv + bqkv
        q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
        k_all = jax.lax.dynamic_update_slice(k_cache, k_new, (pos0, 0))
        v_all = jax.lax.dynamic_update_slice(v_cache, v_new, (pos0, 0))
        mask = ref.causal_cache_mask(s, t, pos0)
        out = attn_kernel.attention_core(
            q.reshape(s, heads, hd), k_all.reshape(t, heads, hd),
            v_all.reshape(t, heads, hd), mask).reshape(s, hidden)
        return (h + out @ wo + bo, k_new, v_new)

    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((s, hidden), f32),
        jax.ShapeDtypeStruct((hidden,), f32),
        jax.ShapeDtypeStruct((hidden,), f32),
        jax.ShapeDtypeStruct((hidden, 3 * hidden), f32),
        jax.ShapeDtypeStruct((3 * hidden,), f32),
        jax.ShapeDtypeStruct((hidden, hidden), f32),
        jax.ShapeDtypeStruct((hidden,), f32),
        jax.ShapeDtypeStruct((t, hidden), f32),
        jax.ShapeDtypeStruct((t, hidden), f32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    return fn, args


def make_gate(spec: ModelSpec, s: int) -> Tuple[Callable, List]:
    """``(h[S,H], ln_g, ln_b, wg[H,K]) → (xln[S,H], w[S,topk], idx[S,topk])``"""

    def fn(h, ln_g, ln_b, wg):
        xln, w, idx = ref.gate_block(h, ln_g, ln_b, wg, spec.topk)
        return (xln, w, idx)

    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((s, spec.hidden), f32),
        jax.ShapeDtypeStruct((spec.hidden,), f32),
        jax.ShapeDtypeStruct((spec.hidden,), f32),
        jax.ShapeDtypeStruct((spec.hidden, spec.experts), f32),
    ]
    return fn, args


def make_expert_ffn(hidden: int, f: int, n: int,
                    act: str) -> Tuple[Callable, List]:
    """``(x[n,H], w1[H,F], b1[F], w2[F,H], b2[H]) → (y[n,H],)``

    The Pallas kernel entry point — shared by local & remote experts and
    by the shared expert (different F).
    """

    def fn(x, w1, b1, w2, b2):
        return (ffn_kernel.expert_ffn(x, w1, b1, w2, b2, act),)

    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((n, hidden), f32),
        jax.ShapeDtypeStruct((hidden, f), f32),
        jax.ShapeDtypeStruct((f,), f32),
        jax.ShapeDtypeStruct((f, hidden), f32),
        jax.ShapeDtypeStruct((hidden,), f32),
    ]
    return fn, args


def make_lm_head(spec: ModelSpec, s: int) -> Tuple[Callable, List]:
    """``(h[S,H], lnf_g, lnf_b, wte[V,H]) → (logits[S,V],)``"""

    def fn(h, lnf_g, lnf_b, wte):
        return (ref.lm_head(h, lnf_g, lnf_b, wte),)

    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((s, spec.hidden), f32),
        jax.ShapeDtypeStruct((spec.hidden,), f32),
        jax.ShapeDtypeStruct((spec.hidden,), f32),
        jax.ShapeDtypeStruct((spec.vocab, spec.hidden), f32),
    ]
    return fn, args


def entry_points(spec: ModelSpec, seq_buckets, expert_buckets
                 ) -> Dict[str, Tuple[Callable, List, Dict]]:
    """All artifacts for one model: name → (fn, example_args, meta)."""
    out: Dict[str, Tuple[Callable, List, Dict]] = {}
    for s in seq_buckets:
        fn, args = make_embed(spec, s)
        out[f"{spec.name}/embed_s{s}"] = (fn, args, {"kind": "embed", "bucket": s})
        fn, args = make_attn(spec, s)
        out[f"{spec.name}/attn_s{s}"] = (fn, args, {"kind": "attn", "bucket": s})
        fn, args = make_gate(spec, s)
        out[f"{spec.name}/gate_s{s}"] = (fn, args, {"kind": "gate", "bucket": s})
        fn, args = make_lm_head(spec, s)
        out[f"{spec.name}/lm_head_s{s}"] = (fn, args, {"kind": "lm_head", "bucket": s})
    for n in expert_buckets:
        fn, args = make_expert_ffn(spec.hidden, spec.ffn, n, spec.act)
        out[f"{spec.name}/expert_n{n}"] = (fn, args, {"kind": "expert", "bucket": n})
        if spec.shared_experts:
            fn, args = make_expert_ffn(spec.hidden, spec.shared_ffn, n, spec.act)
            out[f"{spec.name}/shared_n{n}"] = (fn, args,
                                               {"kind": "shared", "bucket": n})
    return out
