//! Minimal offline shim of the `log` facade: levels, `Record`/
//! `Metadata`, the `Log` trait, a global boxed logger, and the usual
//! `error!`..`trace!` macros. API-compatible with the subset the
//! workspace uses (see util/logger.rs).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn max_level() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Macro plumbing — not part of the public API of the real crate, but
/// kept `pub` so the exported macros can reach it via `$crate`.
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info == LevelFilter::Info);
    }

    #[test]
    fn logging_without_logger_is_a_noop() {
        set_max_level(LevelFilter::Trace);
        info!("no logger installed, still fine {}", 1);
    }
}
