//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The production PJRT path needs the real `xla-rs` bindings plus the
//! XLA shared library — neither is available in this container. This
//! stub keeps the whole artifact/runtime layer *compiling* with the
//! exact call signatures the crate uses; every entry point that would
//! touch PJRT returns an `Error` at runtime. Call sites already gate
//! on `artifacts/manifest.json` existing, and `Runtime::cpu()` fails
//! before any executable can be constructed, so the stubbed execution
//! paths are unreachable in practice.

use std::fmt;

/// Error carrying a static reason; implements `std::error::Error` so
/// `?` converts it into `anyhow::Error` at the call sites.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error { msg: format!("{what}: PJRT runtime not available in this build (xla stub)") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the literal layer distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Unsupported,
}

/// Native element marker for the generic staging/readback entry points.
pub trait Element: Copy {
    const TY: ElementType;
}

impl Element for f32 {
    const TY: ElementType = ElementType::F32;
}

impl Element for i32 {
    const TY: ElementType = ElementType::S32;
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal. Constructible (so `to_literal` helpers compile)
/// but not executable.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Element>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_literal_sync"))
    }
}

/// Device-resident buffer (never actually constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<L: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Element>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
    }

    #[test]
    fn literal_paths_error_not_panic() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
