//! Minimal offline shim of the `anyhow` API surface this workspace
//! uses: `Error`, `Result`, `anyhow!`, `bail!`, `ensure!` and the
//! `Context` extension trait. The container image has no crates.io
//! access, so this stands in for the real crate with the same calling
//! conventions (context chains render as `outer: inner: ...`).

use std::error::Error as StdError;
use std::fmt;

/// A string-chained error: `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((head, rest)) => {
                write!(f, "{head}")?;
                for cause in rest {
                    write!(f, "\n\nCaused by:\n    {cause}")?;
                }
                Ok(())
            }
            None => write!(f, "unknown error"),
        }
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`,
// exactly like the real anyhow — that is what makes this blanket
// conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::option::Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let e: Error = Err::<(), _>(io_err()).context("opening artifact").unwrap_err();
        assert_eq!(e.to_string(), "opening artifact: gone");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x < 100);
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(200).unwrap_err().to_string().contains("x < 100"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }
}
