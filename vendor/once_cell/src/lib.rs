//! Minimal offline shim of `once_cell::sync::OnceCell`, backed by
//! `std::sync::OnceLock` (stable since 1.70).

pub mod sync {
    /// Thread-safe one-shot cell.
    #[derive(Debug, Default)]
    pub struct OnceCell<T>(std::sync::OnceLock<T>);

    impl<T> OnceCell<T> {
        pub const fn new() -> OnceCell<T> {
            OnceCell(std::sync::OnceLock::new())
        }

        pub fn get(&self) -> Option<&T> {
            self.0.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.0.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.0.get_or_init(f)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::OnceCell;

    #[test]
    fn set_once_then_read() {
        static CELL: OnceCell<u32> = OnceCell::new();
        assert!(CELL.get().is_none());
        assert!(CELL.set(7).is_ok());
        assert!(CELL.set(9).is_err());
        assert_eq!(CELL.get(), Some(&7));
    }
}
